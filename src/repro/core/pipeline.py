"""Dataflow pipeline executors — the template realized on a device mesh.

Two executors, mirroring the two ways the paper's template shows up on TPU:

* :class:`SystolicPipeline` (heterogeneous stages).  Runs a
  :class:`~repro.core.decouple.DecoupledProgram` over a ``stage`` mesh axis:
  device *s* executes pipeline stage *s*; channel payloads move one hop per
  tick via ``lax.ppermute`` (the ICI link is the FIFO wire, the per-device
  word buffer is the FIFO storage).  Microbatch *m* occupies stage *s* at
  tick ``t = m + s`` — exactly the paper's Fig. 2 schedule, where a stall in
  one stage does not halt the others.

* :func:`pipeline_apply` (homogeneous stages — classic pipeline parallelism).
  One stage function, per-stage parameters sharded over the ``stage`` axis;
  GPipe-style fill/drain schedule with ``M`` microbatches (bubble fraction
  ``(S-1)/(M+S-1)``).  Differentiable: ``jax.grad`` flows through the
  ``ppermute``s, so the same executor trains (GPipe) and serves.

Both have a pure-Python *emulated* mode used by unit tests on a single
device; the shard_map path is exercised by the multi-device subprocess tests
and by the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .channels import ChannelSpec
from .decouple import DecoupledProgram


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """jax.shard_map exists from ~0.6; older releases ship it in
    jax.experimental with check_rep instead of check_vma."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


_shard_map = shard_map_compat


# ---------------------------------------------------------------------------
# Heterogeneous systolic executor over a DecoupledProgram
# ---------------------------------------------------------------------------

def _example_for_var(v: Any) -> jax.Array:
    """Zero example matching the runtime value of a boundary var.

    Must agree exactly with what :class:`ChannelSpec` will see at run time:
    zero-rank avals keep their ``()`` shape, and the dtype is canonicalized
    (e.g. f64 → f32 under disabled x64) so the packed word width of the
    boundary spec matches the packed width of the live payload.
    """
    aval = getattr(v, "aval", None)
    shape = tuple(getattr(aval, "shape", ()) or ())
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        dtype = jnp.float32
    dtype = jax.dtypes.canonicalize_dtype(np.dtype(dtype))
    return jnp.zeros(shape, dtype)


@dataclasses.dataclass
class _BoundarySpec:
    vars: list[Any]
    spec: ChannelSpec


class SystolicPipeline:
    """Execute a decoupled program as a systolic pipeline over microbatches.

    Channels between non-adjacent stages are linearized: boundary *b* carries
    every var produced by stages ``<= b`` and still needed by stages ``> b``
    (intermediate stages forward them).  All boundaries are padded to one
    transport width so a single ``ppermute`` word per tick suffices.

    ``stream_argnums`` are the positions of the original function's arguments
    that vary per microbatch (leading axis = microbatch); the remaining
    arguments are per-stage constants (weights), available to every stage.
    """

    def __init__(self, prog: DecoupledProgram,
                 stream_argnums: Sequence[int] = (0,)):
        self.prog = prog
        self.stream_argnums = tuple(stream_argnums)
        self.num_stages = len(prog.stages)
        self._build_boundaries()

    # -- static analysis ----------------------------------------------------

    def _build_boundaries(self) -> None:
        prog = self.prog
        S = self.num_stages
        produced_at: dict[Any, int] = {}
        for sp in prog.stages:
            for v in sp.out_vars:
                produced_at[v] = sp.stage_id
        needed_from: dict[Any, int] = {}
        for sp in prog.stages:
            for (tag, ref), v in zip(sp.in_from, sp.in_vars):
                if tag == "chan":
                    needed_from[v] = max(needed_from.get(v, -1), sp.stage_id)
        # final outputs must survive to the last boundary
        for tag, ref in prog.out_sources:
            if tag == "chan":
                needed_from[ref] = max(needed_from.get(ref, -1), S - 1)

        self.boundaries: list[_BoundarySpec] = []
        for b in range(S):  # boundary b sits after stage b
            vars_b = [v for v, p in produced_at.items()
                      if p <= b and needed_from.get(v, -1) > b
                      or (p <= b and b == S - 1 and any(
                          t == "chan" and r is v
                          for t, r in prog.out_sources))]
            # deterministic order
            vars_b = sorted(set(vars_b), key=lambda v: (produced_at[v],
                                                        str(v)))
            example = tuple(_example_for_var(v) for v in vars_b)
            self.boundaries.append(
                _BoundarySpec(vars_b, ChannelSpec.from_example(example)))
        self.width = max([1] + [b.spec.width for b in self.boundaries])

    # -- per-stage wrapped function ------------------------------------------

    def _stage_fn(self, s: int) -> Callable:
        prog = self.prog
        sp = prog.stages[s]
        in_spec = self.boundaries[s - 1] if s > 0 else None
        out_spec = self.boundaries[s]
        consts = prog.partition.cdfg.closed_jaxpr.consts

        def fn(word_in: jax.Array, stream_args: tuple,
               const_args: dict[int, Any]):
            env: dict[Any, Any] = {}
            if in_spec is not None and in_spec.vars:
                payload = in_spec.spec.unpack(word_in[:in_spec.spec.width])
                for v, val in zip(in_spec.vars, payload):
                    env[v] = val
            args_map: dict[int, Any] = {}
            for i, a in zip(self.stream_argnums, stream_args):
                args_map[i] = a
            ins = []
            for (tag, ref), v in zip(sp.in_from, sp.in_vars):
                if tag == "arg":
                    ins.append(args_map[ref] if ref in args_map
                               else const_args[ref])
                elif tag == "const":
                    ins.append(consts[ref])
                else:
                    ins.append(env[v])
            outs = sp.fn(*ins)
            for v, o in zip(sp.out_vars, outs):
                env[v] = o
            payload_out = tuple(env[v] for v in out_spec.vars)
            word_out = out_spec.spec.pack(payload_out, pad_to=self.width)
            if s == self.num_stages - 1:
                res = []
                for tag, ref in prog.out_sources:
                    if tag == "chan":
                        res.append(env[ref])
                    elif tag == "arg":
                        res.append(args_map[ref] if ref in args_map
                                   else const_args[ref])
                    elif tag == "const":
                        res.append(consts[ref])
                    else:
                        res.append(jnp.asarray(ref))
                y = tuple(res)
            else:
                y = None
            return word_out, y

        return fn

    # -- emulated execution (single device, schedule-exact) -------------------

    def run_emulated(self, *args: Any) -> tuple:
        """Run the exact tick/ppermute schedule in Python (one device).

        Produces the same numerics as the shard_map executor and the same
        per-tick occupancy; used for schedule unit tests and CPU demos.
        """
        S = self.num_stages
        stream = [args[i] for i in self.stream_argnums]
        T = int(jax.tree_util.tree_leaves(stream[0])[0].shape[0])
        const_args = {j: a for j, a in enumerate(args)
                      if j not in self.stream_argnums}
        fns = [self._stage_fn(s) for s in range(S)]

        words = [jnp.zeros((self.width,), jnp.uint32) for _ in range(S)]
        outputs: list[Any] = [None] * T
        for t in range(T + S - 1):
            new_words = list(words)
            for s in range(S):
                m = t - s
                if not (0 <= m < T):
                    continue
                xs = tuple(jax.tree_util.tree_map(lambda a: a[m], x)
                           for x in stream)
                word_in = words[s - 1] if s > 0 else jnp.zeros(
                    (self.width,), jnp.uint32)
                w_out, y = fns[s](word_in, xs, const_args)
                new_words[s] = w_out
                if s == S - 1:
                    outputs[m] = y
            # ppermute: boundary words shift one stage per tick.  We emulate
            # by double-buffering: stage s+1 at tick t+1 reads stage s's
            # output from tick t.
            words = new_words
        outs = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outputs)
        return outs

    # -- shard_map execution ---------------------------------------------------

    def build_sharded(self, mesh: Mesh, axis: str = "stage") -> Callable:
        """Return ``run(*args) -> stacked outputs`` executing on ``mesh``
        with one pipeline stage per device along ``axis``."""
        S = self.num_stages
        if mesh.shape[axis] != S:
            raise ValueError(
                f"mesh axis {axis!r} has size {mesh.shape[axis]}, "
                f"need {S} (one device per stage)")
        fns = [self._stage_fn(s) for s in range(S)]

        def run(*args: Any):
            stream = [args[i] for i in self.stream_argnums]
            T = int(jax.tree_util.tree_leaves(stream[0])[0].shape[0])
            const_args = {j: a for j, a in enumerate(args)
                          if j not in self.stream_argnums}

            # probe output structure once (stage S-1 on microbatch 0)
            xs0 = tuple(jax.tree_util.tree_map(lambda a: a[0], x)
                        for x in stream)
            _, y0 = jax.eval_shape(
                lambda w, xs, ca: fns[S - 1](w, xs, ca),
                jax.ShapeDtypeStruct((self.width,), jnp.uint32),
                xs0, const_args)

            def per_device(stream_dev, *const_flat):
                const_args_dev = jax.tree_util.tree_unflatten(
                    const_treedef, const_flat)
                sidx = jax.lax.axis_index(axis)

                def tick(carry, t):
                    word, out_buf = carry
                    m = t - sidx
                    valid = (m >= 0) & (m < T)
                    m_c = jnp.clip(m, 0, T - 1)
                    xs = tuple(jax.tree_util.tree_map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, m_c, 0, keepdims=False), x)
                        for x in stream_dev)

                    branches = []
                    for s in range(S):
                        def mk(s):
                            def br(w, xs_):
                                w_out, y = fns[s](w, xs_, const_args_dev)
                                if y is None:
                                    y = jax.tree_util.tree_map(
                                        lambda sd: jnp.zeros(sd.shape,
                                                             sd.dtype), y0)
                                return w_out, y
                            return br
                        branches.append(mk(s))
                    w_out, y = jax.lax.switch(sidx, branches, word, xs)

                    write = valid & (sidx == S - 1)
                    out_buf = jax.tree_util.tree_map(
                        lambda buf, yv: jnp.where(
                            write,
                            jax.lax.dynamic_update_index_in_dim(
                                buf, yv, m_c, 0),
                            buf),
                        out_buf, y)
                    w_next = jax.lax.ppermute(
                        w_out, axis,
                        [(i, (i + 1) % S) for i in range(S)])
                    return (w_next, out_buf), None

                out_buf0 = jax.tree_util.tree_map(
                    lambda sd: jnp.zeros((T,) + sd.shape, sd.dtype), y0)
                word0 = jnp.zeros((self.width,), jnp.uint32)
                (_, out_buf), _ = jax.lax.scan(
                    tick, (word0, out_buf0), jnp.arange(T + S - 1))
                # every device returns a buffer; only stage S-1's is real.
                # psum the masked buffers so the result is replicated.
                out_buf = jax.tree_util.tree_map(
                    lambda b: jax.lax.psum(
                        jnp.where(sidx == S - 1, b,
                                  jnp.zeros_like(b)), axis),
                    out_buf)
                return out_buf

            const_flat, const_treedef = jax.tree_util.tree_flatten(const_args)
            shard = _shard_map(
                per_device, mesh=mesh,
                in_specs=(P(),) * (1 + len(const_flat)),
                out_specs=P())
            return shard(tuple(stream), *const_flat)

        return run


# ---------------------------------------------------------------------------
# Homogeneous pipeline parallelism (classic PP with the template's channels)
# ---------------------------------------------------------------------------

def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,
    microbatches: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "stage",
) -> jax.Array:
    """GPipe-style forward over ``S = mesh.shape[axis]`` stages.

    ``stage_params`` leaves have leading dim ``S`` (sharded over ``axis``);
    ``microbatches`` has shape ``(M, ...)`` (replicated).  Returns ``(M, ...)``
    outputs (replicated).  Differentiable — ``jax.grad`` through the
    ``ppermute`` gives the reverse pipeline automatically.
    """
    S = mesh.shape[axis]
    M = microbatches.shape[0]

    def per_device(params_blk, mb):
        params_s = jax.tree_util.tree_map(lambda p: p[0], params_blk)
        sidx = jax.lax.axis_index(axis)

        def tick(carry, t):
            act_in, out_buf = carry
            m = t - sidx
            valid = (m >= 0) & (m < M)
            m_c = jnp.clip(m, 0, M - 1)
            x0 = jax.lax.dynamic_index_in_dim(mb, m_c, 0, keepdims=False)
            x = jnp.where(sidx == 0, x0, act_in)
            y = stage_fn(params_s, x)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            out_buf = jnp.where(
                valid & (sidx == S - 1),
                jax.lax.dynamic_update_index_in_dim(out_buf, y, m_c, 0),
                out_buf)
            act_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)])
            return (act_next, out_buf), None

        zero_act = jnp.zeros(mb.shape[1:], mb.dtype)
        out0 = jnp.zeros_like(mb)
        (_, out_buf), _ = jax.lax.scan(
            tick, (zero_act, out0), jnp.arange(M + S - 1))
        out_buf = jax.lax.psum(
            jnp.where(sidx == S - 1, out_buf, jnp.zeros_like(out_buf)),
            axis)
        return out_buf

    return _shard_map(
        per_device, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )(stage_params, microbatches)


def pipeline_apply_emulated(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,
    microbatches: jax.Array,
    num_stages: int,
) -> jax.Array:
    """Schedule-exact single-device emulation of :func:`pipeline_apply`."""
    S = num_stages
    M = microbatches.shape[0]
    acts = [jnp.zeros(microbatches.shape[1:], microbatches.dtype)
            for _ in range(S)]
    outs = [None] * M
    for t in range(M + S - 1):
        new_acts = list(acts)
        for s in range(S):
            m = t - s
            if not (0 <= m < M):
                continue
            x = microbatches[m] if s == 0 else acts[s - 1]
            p = jax.tree_util.tree_map(lambda q: q[s], stage_params)
            y = stage_fn(p, x)
            new_acts[s] = y
            if s == S - 1:
                outs[m] = y
        acts = new_acts
    return jnp.stack(outs)


def gpipe_bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """Fill/drain overhead of the schedule (paper Fig. 2's ramp)."""
    return (num_stages - 1) / (num_microbatches + num_stages - 1)

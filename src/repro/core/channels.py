"""FIFO channels — the template's communication primitive (§II, §III-A).

Three realizations of the paper's FIFO, one per level of the TPU stack:

* :class:`ChannelSpec` — packs an arbitrary pytree payload into a flat
  ``uint32`` transport word so heterogeneous stage boundaries can share one
  physical channel (the pipeline executor ships one fixed-width word per tick
  via ``lax.ppermute``; bitcasting is free on TPU).
* :class:`DeviceFIFO` — a bounded ring buffer materialized as a device array
  (functional push/pop), used for depth>1 channels inside scanned loops:
  this is the direct analogue of the BRAM FIFO between two accelerator
  stages.
* :class:`HostFIFO` — a bounded, thread-backed queue for the input pipeline
  (host → device prefetch), giving the data-loading stage the same decoupled
  producer/consumer behaviour the paper gives memory stages.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Payload packing
# ---------------------------------------------------------------------------

def _words_for(aval_shape: Sequence[int], dtype: np.dtype) -> int:
    n = int(np.prod(aval_shape)) if len(aval_shape) else 1
    nbytes = n * np.dtype(dtype).itemsize
    return (nbytes + 3) // 4


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    shape: tuple[int, ...]
    dtype: Any
    words: int


@dataclasses.dataclass
class ChannelSpec:
    """Pack/unpack a fixed-structure pytree to/from a flat uint32 word."""

    treedef: Any
    leaves: list[LeafSpec]
    width: int  # total uint32 words

    @classmethod
    def from_example(cls, example: Any) -> "ChannelSpec":
        flat, treedef = jax.tree_util.tree_flatten(example)
        leaves = []
        for x in flat:
            x = jnp.asarray(x)
            leaves.append(LeafSpec(tuple(x.shape), x.dtype,
                                   _words_for(x.shape, x.dtype)))
        width = sum(l.words for l in leaves)
        return cls(treedef, leaves, width)

    def pack(self, payload: Any, pad_to: int | None = None) -> jax.Array:
        flat = jax.tree_util.tree_leaves(payload)
        words = []
        for spec, x in zip(self.leaves, flat):
            x = jnp.asarray(x, spec.dtype).reshape(-1)
            itemsize = np.dtype(spec.dtype).itemsize
            if itemsize == 4:
                w = jax.lax.bitcast_convert_type(x, jnp.uint32)
            elif itemsize == 2:
                w16 = jax.lax.bitcast_convert_type(x, jnp.uint16)
                if w16.size % 2:
                    w16 = jnp.concatenate([w16, jnp.zeros((1,), jnp.uint16)])
                w = (w16[0::2].astype(jnp.uint32)
                     | (w16[1::2].astype(jnp.uint32) << 16))
            elif itemsize == 1:
                w8 = jax.lax.bitcast_convert_type(x, jnp.uint8)
                pad = (-w8.size) % 4
                if pad:
                    w8 = jnp.concatenate([w8, jnp.zeros((pad,), jnp.uint8)])
                w8 = w8.reshape(-1, 4).astype(jnp.uint32)
                w = (w8[:, 0] | (w8[:, 1] << 8) | (w8[:, 2] << 16)
                     | (w8[:, 3] << 24))
            elif itemsize == 8:
                w64 = jax.lax.bitcast_convert_type(x, jnp.uint64) \
                    if x.dtype != jnp.uint64 else x
                w = jnp.stack([(w64 & 0xFFFFFFFF).astype(jnp.uint32),
                               (w64 >> 32).astype(jnp.uint32)],
                              axis=-1).reshape(-1)
            else:  # pragma: no cover
                raise NotImplementedError(f"itemsize {itemsize}")
            words.append(w)
        out = (jnp.concatenate(words) if words
               else jnp.zeros((0,), jnp.uint32))
        if pad_to is not None and pad_to > out.size:
            out = jnp.concatenate(
                [out, jnp.zeros((pad_to - out.size,), jnp.uint32)])
        return out

    def unpack(self, word: jax.Array) -> Any:
        flat = []
        off = 0
        for spec in self.leaves:
            w = word[off:off + spec.words]
            off += spec.words
            n = int(np.prod(spec.shape)) if spec.shape else 1
            itemsize = np.dtype(spec.dtype).itemsize
            if itemsize == 4:
                x = jax.lax.bitcast_convert_type(w, spec.dtype)
            elif itemsize == 2:
                lo = (w & 0xFFFF).astype(jnp.uint16)
                hi = (w >> 16).astype(jnp.uint16)
                x16 = jnp.stack([lo, hi], axis=-1).reshape(-1)[:n]
                x = jax.lax.bitcast_convert_type(x16, spec.dtype)
            elif itemsize == 1:
                b = jnp.stack([(w >> s) & 0xFF for s in (0, 8, 16, 24)],
                              axis=-1).reshape(-1)[:n].astype(jnp.uint8)
                x = jax.lax.bitcast_convert_type(b, spec.dtype)
            elif itemsize == 8:
                lo = w[0::2].astype(jnp.uint64)
                hi = w[1::2].astype(jnp.uint64)
                x64 = lo | (hi << 32)
                x = (x64 if spec.dtype == jnp.uint64
                     else jax.lax.bitcast_convert_type(x64, spec.dtype))
            else:  # pragma: no cover
                raise NotImplementedError(f"itemsize {itemsize}")
            flat.append(x[:n].reshape(spec.shape))
        return jax.tree_util.tree_unflatten(self.treedef, flat)


# ---------------------------------------------------------------------------
# Device-side bounded FIFO (functional ring buffer)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FIFOState:
    buf: jax.Array    # (depth, width) uint32
    head: jax.Array   # scalar int32: next pop position
    count: jax.Array  # scalar int32: occupancy


class DeviceFIFO:
    """Bounded FIFO over fixed-width uint32 words, usable inside scan.

    Functional: every op returns a new :class:`FIFOState`.  Push on a full
    FIFO and pop on an empty one are guarded by the caller via
    :meth:`can_push` / :meth:`can_pop` masks (backpressure — §II's bounded
    channels are what localize stalls).
    """

    def __init__(self, depth: int, width: int):
        self.depth = depth
        self.width = width

    def init(self) -> FIFOState:
        return FIFOState(
            buf=jnp.zeros((self.depth, self.width), jnp.uint32),
            head=jnp.zeros((), jnp.int32),
            count=jnp.zeros((), jnp.int32),
        )

    def can_push(self, s: FIFOState) -> jax.Array:
        return s.count < self.depth

    def can_pop(self, s: FIFOState) -> jax.Array:
        return s.count > 0

    def push(self, s: FIFOState, word: jax.Array,
             enable: jax.Array | bool = True) -> FIFOState:
        enable = jnp.asarray(enable) & self.can_push(s)
        tail = (s.head + s.count) % self.depth
        buf = jax.lax.cond(
            enable,
            lambda: jax.lax.dynamic_update_index_in_dim(
                s.buf, word.astype(jnp.uint32), tail, 0),
            lambda: s.buf,
        )
        return FIFOState(buf, s.head,
                         s.count + enable.astype(jnp.int32))

    def pop(self, s: FIFOState,
            enable: jax.Array | bool = True) -> tuple[jax.Array, FIFOState]:
        enable = jnp.asarray(enable) & self.can_pop(s)
        word = jax.lax.dynamic_index_in_dim(s.buf, s.head, 0,
                                            keepdims=False)
        new_head = jnp.where(enable, (s.head + 1) % self.depth, s.head)
        return word, FIFOState(s.buf, new_head,
                               s.count - enable.astype(jnp.int32))


jax.tree_util.register_dataclass(
    FIFOState, data_fields=["buf", "head", "count"], meta_fields=[])


# ---------------------------------------------------------------------------
# Host-side bounded prefetch FIFO (input pipeline decoupling)
# ---------------------------------------------------------------------------

class HostFIFO:
    """Producer thread fills a bounded queue; consumer iterates.

    Applies the template to the host→device boundary: data production
    (tokenization, sharding, H2D transfer) is its own pipeline stage whose
    latency is hidden as long as the queue is non-empty, exactly like a
    memory-access stage feeding a compute stage in §II.
    """

    _SENTINEL = object()

    def __init__(self, source: Iterator[Any], depth: int = 4,
                 transform: Callable[[Any], Any] | None = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._source = source
        self._transform = transform
        self._err: BaseException | None = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        try:
            for item in self._source:
                if self._transform is not None:
                    item = self._transform(item)
                self._q.put(item)
        except BaseException as e:  # surfaced on next __next__
            self._err = e
        finally:
            self._q.put(self._SENTINEL)

    def __iter__(self) -> "HostFIFO":
        return self

    def __next__(self) -> Any:
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    @property
    def occupancy(self) -> int:
        return self._q.qsize()

"""Access/execute decoupling: turn a :class:`Partition` into executable
stage functions connected by explicit channel values.

This is the analogue of the paper's §IV "hardware generation": each pipeline
stage's sub-CDFG is emitted as an independent unit ("synthesizable C, one
statement per LLVM instruction") and handed to the backend.  Here each stage
becomes an independent JAX callable — a one-to-one replay of its jaxpr
equations via ``primitive.bind`` — which XLA compiles separately when used by
the pipeline executor.  Cross-stage vars are the FIFO payloads.

The decoupled program is *semantically identical* to the original function:
:func:`run_stages_sequential` replays all stages in topological order and is
tested for exact equality against the direct call.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

from jax.extend import core as jex_core

from .cdfg import CDFG
from .partition import Partition


@dataclasses.dataclass
class StageProgram:
    """An executable stage: ``fn(*inputs) -> tuple(outputs)``.

    ``in_vars`` / ``out_vars`` give the jaxpr vars consumed / produced, in
    positional order.  ``in_from`` tags each input as coming from the
    original function arguments (``("arg", i)``), a constant
    (``("const", i)``) or an upstream channel (``("chan", var)``).
    """

    stage_id: int
    fn: Callable
    in_vars: list[Any]
    out_vars: list[Any]
    in_from: list[tuple]
    eqn_count: int


@dataclasses.dataclass
class DecoupledProgram:
    partition: Partition
    stages: list[StageProgram]
    #: (var) -> producing stage id, for channel routing
    producer_stage: dict[Any, int]
    out_sources: list[tuple]  # ("chan", var) | ("arg", i) | ("const", i)

    def __len__(self) -> int:
        return len(self.stages)


def _make_stage_fn(eqns: Sequence[Any], in_vars: Sequence[Any],
                   out_vars: Sequence[Any]) -> Callable:
    """Build an interpreter that replays ``eqns`` (autodidax-style)."""

    def fn(*args):
        env: dict[Any, Any] = {}

        def read(v):
            if isinstance(v, jex_core.Literal):
                return v.val
            return env[v]

        for var, val in zip(in_vars, args):
            env[var] = val
        for eqn in eqns:
            invals = [read(v) for v in eqn.invars]
            outs = eqn.primitive.bind(*invals, **eqn.params)
            if eqn.primitive.multiple_results:
                for ov, o in zip(eqn.outvars, outs):
                    env[ov] = o
            else:
                env[eqn.outvars[0]] = outs
        return tuple(env[v] for v in out_vars)

    return fn


def decouple(partition: Partition) -> DecoupledProgram:
    """Emit one executable program per pipeline stage."""
    cdfg: CDFG = partition.cdfg
    jaxpr = cdfg.closed_jaxpr.jaxpr
    invar_idx = {v: i for i, v in enumerate(jaxpr.invars)}
    constvar_idx = {v: i for i, v in enumerate(jaxpr.constvars)}

    # var -> producing node
    producer_node: dict[Any, int] = {}
    for n in cdfg.nodes:
        for ov in n.eqn.outvars:
            producer_node[ov] = n.id

    producer_stage: dict[Any, int] = {
        v: partition.stage_of_node[nid] for v, nid in producer_node.items()
    }

    out_needed_by_stage: dict[int, set] = {s.id: set() for s in
                                           partition.stages}
    # vars needed as final outputs
    final_out_vars = set()
    for ov in jaxpr.outvars:
        if isinstance(ov, jex_core.Literal):
            continue
        if ov in producer_stage:
            out_needed_by_stage[producer_stage[ov]].add(ov)
            final_out_vars.add(ov)

    stages_programs: list[StageProgram] = []
    for stage in partition.stages:
        node_ids = list(stage.node_ids)
        # §III-B1: prepend duplicated cheap producers
        dup_ids = [nid for nid, consumers in partition.duplicated.items()
                   if stage.id in consumers]
        eqn_ids = sorted(set(node_ids) | set(dup_ids))
        eqns = [cdfg.node(nid).eqn for nid in eqn_ids]
        defined = {ov for e in eqns for ov in e.outvars}

        in_vars: list[Any] = []
        in_from: list[tuple] = []
        seen_in = set()
        for eqn in eqns:
            for iv in eqn.invars:
                if isinstance(iv, jex_core.Literal) or iv in defined:
                    continue
                if iv in seen_in:
                    continue
                seen_in.add(iv)
                in_vars.append(iv)
                if iv in invar_idx:
                    in_from.append(("arg", invar_idx[iv]))
                elif iv in constvar_idx:
                    in_from.append(("const", constvar_idx[iv]))
                else:
                    src = producer_stage.get(iv)
                    if src is None or src == stage.id:
                        raise AssertionError(
                            f"stage {stage.id}: unresolved input {iv}")
                    in_from.append(("chan", iv))

        # outputs: vars produced here and consumed by later stages or final
        out_vars: list[Any] = []
        consumed_later = set()
        for e in cdfg.edges:
            if e.var is None:
                continue
            s_src = partition.stage_of_node.get(e.src)
            s_dst = partition.stage_of_node.get(e.dst)
            if s_src == stage.id and s_dst != stage.id:
                # consumers that received a duplicated copy don't need it
                if (e.src in partition.duplicated
                        and s_dst in partition.duplicated[e.src]):
                    continue
                consumed_later.add(e.var)
        for v in sorted(consumed_later | out_needed_by_stage[stage.id],
                        key=lambda v: producer_node.get(v, -1)):
            # only vars actually produced by this stage's eqns
            if v in defined:
                out_vars.append(v)

        stages_programs.append(StageProgram(
            stage_id=stage.id,
            fn=_make_stage_fn(eqns, in_vars, out_vars),
            in_vars=in_vars,
            out_vars=out_vars,
            in_from=in_from,
            eqn_count=len(eqns),
        ))

    out_sources: list[tuple] = []
    for ov in jaxpr.outvars:
        if isinstance(ov, jex_core.Literal):
            out_sources.append(("lit", ov.val))
        elif ov in producer_stage:
            out_sources.append(("chan", ov))
        elif ov in invar_idx:
            out_sources.append(("arg", invar_idx[ov]))
        else:
            out_sources.append(("const", constvar_idx[ov]))

    return DecoupledProgram(partition, stages_programs, producer_stage,
                            out_sources)


def run_stages_sequential(prog: DecoupledProgram, *args: Any) -> tuple:
    """Semantic-equivalence executor: replay stages in order, materializing
    channel values.  Must produce bit-identical results to the original
    function (this is the correctness oracle for the pipeline executors)."""
    consts = prog.partition.cdfg.closed_jaxpr.consts
    chan_env: dict[Any, Any] = {}
    for sp in prog.stages:
        ins = []
        for (tag, ref), var in zip(sp.in_from, sp.in_vars):
            if tag == "arg":
                ins.append(args[ref])
            elif tag == "const":
                ins.append(consts[ref])
            else:
                ins.append(chan_env[var])
        outs = sp.fn(*ins)
        for v, o in zip(sp.out_vars, outs):
            chan_env[v] = o
    results = []
    for tag, ref in prog.out_sources:
        if tag == "chan":
            results.append(chan_env[ref])
        elif tag == "arg":
            results.append(args[ref])
        elif tag == "const":
            results.append(consts[ref])
        else:
            results.append(ref)
    return tuple(results)


def decoupled_call(fn: Callable, *example_args: Any,
                   policy: str = "paper", **partition_kwargs: Any) -> Callable:
    """One-shot convenience: trace → partition → decouple → return a callable
    that executes the staged program (jit-able; semantically == ``fn``)."""
    from .cdfg import CDFG as _CDFG
    from .partition import partition_cdfg

    cdfg = _CDFG.from_function(fn, *example_args)
    part = partition_cdfg(cdfg, policy=policy, **partition_kwargs)
    prog = decouple(part)

    def staged(*args):
        out = run_stages_sequential(prog, *args)
        return out if len(out) != 1 else out[0]

    staged.program = prog  # type: ignore[attr-defined]
    return staged

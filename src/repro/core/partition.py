"""Algorithm 1 — partitioning the CDFG onto the dataflow template (§III-A).

Faithful transcription of the paper's pseudocode::

    procedure PartitionCDFG(G)
        SCCs            <- allStronglyConnComps(G)
        DAG             <- collapse(SCCs, G)
        TopoSortedNodes <- topologicalSort(DAG)
        LongSCCs        <- getSCCWithLongOp(SCCs)
        MemNodes        <- findLdStNodes(G)
        MemLongSCC      <- LongSCCs ∪ MemNodes
        allStages <- {};  curStage <- {}
        while TopoSortedNodes ≠ ∅:
            curNode  <- TopoSortedNodes.pop()
            curStage <- curStage ∪ curNode
            if curNode ∈ MemLongSCC:
                allStages <- allStages ∪ curStage
                curStage  <- {}
        return allStages

Notes kept from the paper:

* SCCs are never split across stages — channels add latency, which would
  inflate the initiation interval of the loop they embody (§III, citing
  decoupled software pipelining [7]).
* A new stage is cut **after** every memory operation or long-latency SCC,
  which (a) pipelines many outstanding requests into the memory subsystem and
  (b) localizes stalls (§III-B2).
* The pseudocode drops a trailing non-empty ``curStage``; we append it (the
  intended behaviour — otherwise pure-sink cheap ops would vanish).

Beyond-paper policies (kept separate, selected via ``policy=``):

* ``"fused"``      — everything in one stage: the conventional-HLS end of the
  spectrum (§II); this is the baseline the paper compares against.
* ``"maximal"``    — one stage per node: the fine-grained dataflow machine end.
* ``"cost_aware"`` — Algorithm 1, then merges adjacent stages whose channel
  cost exceeds the stall-localization benefit (FIFO area vs duplication,
  §III-B1 generalized with a cost model).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import networkx as nx

from .cdfg import CDFG, CHEAP_PRIMITIVES, LatencyModel


@dataclasses.dataclass
class Stage:
    """One stage of the dataflow pipeline template."""

    id: int
    node_ids: list[int]
    has_memory: bool
    has_long: bool
    #: abstract cycle cost of the stage body (sum of op latencies)
    latency: int
    #: min initiation interval imposed by dependence cycles inside the stage
    ii: int
    #: memory regions this stage touches (paper: one access interface each)
    regions: tuple[str, ...]
    #: raw dependence-cycle latency (``ii`` before transform scaling:
    #: unroll serializes U recurrence steps per token, so ``ii`` may be
    #: ``U·scc_ii`` — the rewrites need the unscaled value to recompute)
    scc_ii: int = 0

    def __repr__(self) -> str:  # pragma: no cover
        tags = []
        if self.has_memory:
            tags.append("MEM")
        if self.has_long:
            tags.append("LONG")
        return (f"<Stage {self.id}: {len(self.node_ids)} ops lat={self.latency}"
                f" ii={self.ii} {'|'.join(tags)}>")


@dataclasses.dataclass
class Channel:
    """A FIFO channel between two stages (one per crossing var)."""

    src_stage: int
    dst_stage: int
    var: Any | None            # jaxpr var carried; None => pure ordering token
    nbytes: int                # payload width per token
    kind: str = "data"


@dataclasses.dataclass
class Partition:
    cdfg: CDFG
    stages: list[Stage]
    channels: list[Channel]
    stage_of_node: dict[int, int]
    #: nodes replicated into later stages instead of channeled (§III-B1)
    duplicated: dict[int, list[int]] = dataclasses.field(default_factory=dict)
    #: active :class:`repro.dataflow.transforms.TransformConfig` (None =
    #: untransformed); channel widths and stage timing already reflect it
    transforms: Any = None

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    def summary(self) -> str:
        lines = [f"Partition: {self.num_stages} stages, "
                 f"{len(self.channels)} channels"]
        for s in self.stages:
            prims = [self.cdfg.node(n).prim for n in s.node_ids]
            lines.append(f"  stage {s.id}: {prims} "
                         f"(mem={s.has_memory} long={s.has_long} "
                         f"ii={s.ii} lat={s.latency})")
        for c in self.channels:
            v = "token" if c.var is None else str(c.var)
            lines.append(f"  chan s{c.src_stage}->s{c.dst_stage} {v} "
                         f"{c.nbytes}B")
        if self.duplicated:
            lines.append(f"  duplicated nodes: {self.duplicated}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------


def _var_nbytes(var: Any) -> int:
    aval = var.aval
    import numpy as np

    return int(np.prod(aval.shape)) * aval.dtype.itemsize if aval.shape else (
        aval.dtype.itemsize)


def _scc_cycle_latency(cdfg: CDFG, scc: set[int]) -> int:
    """Latency of the dependence cycle inside an SCC (lower-bounds its II)."""
    if len(scc) == 1:
        nid = next(iter(scc))
        has_self = any(e.src == nid and e.dst == nid for e in cdfg.edges)
        return cdfg.node(nid).latency if has_self else 0
    return sum(cdfg.node(n).latency for n in scc)


def _scaled_stage_timing(scc_ii: int, base_latency: int,
                         transforms: Any) -> tuple[int, int]:
    """(ii, latency) of a stage under the active transform config's
    unroll factor: a cyclic SCC serializes its U recurrence steps per
    channel token (``ii = U·scc_ii``, ``latency += (U−1)·scc_ii``);
    acyclic stages replicate U-way spatially and keep their timing.
    The single definition :func:`materialize` and
    :func:`duplicate_cheap_rewrite` share so the scaling cannot drift
    (re-exported as ``repro.dataflow.transforms.scaled_stage_timing``)."""
    U = int(getattr(transforms, "unroll", 1) or 1)
    ii = max(1, scc_ii)
    latency = base_latency
    if U > 1 and scc_ii > 0:
        ii = max(1, scc_ii * U)
        latency += (U - 1) * scc_ii
    return ii, latency


@dataclasses.dataclass
class StagePlan:
    """Intermediate result of Algorithm 1 before materialization: the SCC
    decomposition plus the grouping of SCCs into stages.  Produced by
    :func:`stage_groups`, optionally refined by
    :func:`merge_costly_boundaries`, turned into a :class:`Partition` by
    :func:`materialize`.  Exposed so the compiler driver
    (``repro.dataflow``) can run each step as a named, swappable pass."""

    sccs: list[set[int]]
    scc_of_node: dict[int, int]
    order: list[int]
    mem_long: set[int]
    groups: list[list[int]]


def stage_groups(
    cdfg: CDFG,
    *,
    policy: str = "paper",
) -> StagePlan:
    """Algorithm 1 lines 2-10: SCCs, condensation, topological order,
    classification, and the stage grouping for the chosen policy (without
    the cost-aware merge — that is a separate rewrite)."""
    g = nx.DiGraph()
    for n in cdfg.nodes:
        g.add_node(n.id)
    for e in cdfg.edges:
        g.add_edge(e.src, e.dst)

    # --- Algorithm 1 lines 2-3: SCCs and condensation -----------------------
    sccs = [set(c) for c in nx.strongly_connected_components(g)]
    scc_of_node: dict[int, int] = {}
    for k, comp in enumerate(sccs):
        for nid in comp:
            scc_of_node[nid] = k
    dag = nx.DiGraph()
    dag.add_nodes_from(range(len(sccs)))
    for e in cdfg.edges:
        a, b = scc_of_node[e.src], scc_of_node[e.dst]
        if a != b:
            dag.add_edge(a, b)

    # --- line 4: deterministic topological sort ----------------------------
    order = list(nx.lexicographical_topological_sort(
        dag, key=lambda k: min(sccs[k])))

    # --- lines 5-7: classification ------------------------------------------
    def scc_has_long(k: int) -> bool:
        return any(cdfg.node(n).is_long for n in sccs[k])

    def scc_has_mem(k: int) -> bool:
        return any(cdfg.node(n).is_memory for n in sccs[k])

    mem_long = {k for k in range(len(sccs))
                if scc_has_long(k) or scc_has_mem(k)}

    # --- stage assignment ----------------------------------------------------
    if policy == "fused":
        groups = [list(range(len(sccs)))] if sccs else []
    elif policy == "maximal":
        groups = [[k] for k in order]
    else:  # "paper" and "cost_aware" start from Algorithm 1
        groups = []
        cur: list[int] = []
        for k in order:
            cur.append(k)
            if k in mem_long:
                groups.append(cur)
                cur = []
        if cur:  # trailing stage (pseudocode omission, see module docstring)
            groups.append(cur)

    return StagePlan(sccs, scc_of_node, order, mem_long, groups)


def merge_costly_boundaries(
    cdfg: CDFG,
    plan: StagePlan,
    channel_cost_bytes: int,
) -> StagePlan:
    """Cost-aware rewrite on a :class:`StagePlan` (see
    :func:`_merge_costly_boundaries` for the merge rule)."""
    groups = _merge_costly_boundaries(
        cdfg, plan.sccs, [list(g) for g in plan.groups], channel_cost_bytes)
    return dataclasses.replace(plan, groups=groups)


def materialize(cdfg: CDFG, plan: StagePlan,
                transforms: Any = None) -> Partition:
    """Turn a :class:`StagePlan` into a :class:`Partition` with concrete
    :class:`Stage` records and FIFO channels (no duplication rewrite).
    ``transforms`` (default: the CDFG's annotation from the ``transform``
    pass) scales stage timing and channel widths — see
    :func:`repro.dataflow.transforms.scaled_stage_timing`."""
    if transforms is None:
        transforms = getattr(cdfg, "transforms", None)
    stages: list[Stage] = []
    stage_of_node: dict[int, int] = {}
    for sid, grp in enumerate(plan.groups):
        node_ids = sorted(n for k in grp for n in plan.sccs[k])
        for nid in node_ids:
            stage_of_node[nid] = sid
        scc_ii = max([0] + [_scc_cycle_latency(cdfg, plan.sccs[k])
                            for k in grp])
        ii, latency = _scaled_stage_timing(
            scc_ii, sum(cdfg.node(n).latency for n in node_ids), transforms)
        regions = tuple(sorted({cdfg.node(n).region for n in node_ids
                                if cdfg.node(n).region}))
        stages.append(Stage(
            id=sid,
            node_ids=node_ids,
            has_memory=any(cdfg.node(n).is_memory for n in node_ids),
            has_long=any(cdfg.node(n).is_long for n in node_ids),
            latency=latency,
            ii=ii,
            regions=regions,
            scc_ii=scc_ii,
        ))
    part = Partition(cdfg, stages, [], stage_of_node, transforms=transforms)
    part.channels = derive_channels(part)
    return part


def duplicate_cheap_rewrite(part: Partition) -> Partition:
    """§III-B1 rewrite: replicate cheap producers into consumer stages,
    re-derive the channel set, and fold the duplicated producers' latencies
    into their consumer stages' ``latency`` (the replica executes *inside*
    the consumer, so its cycles belong to that stage's body — the old code
    left consumer latencies at their pre-duplication values and the
    simulator under-estimated those stages).  Latencies are recomputed
    from scratch, so the rewrite is idempotent.  Mutates ``part`` in place
    and returns it."""
    _duplicate_cheap_sccs(part)
    cdfg = part.cdfg
    extra: dict[int, int] = {}
    for nid, consumers in part.duplicated.items():
        for sid in consumers:
            extra[sid] = extra.get(sid, 0) + cdfg.node(nid).latency
    for s in part.stages:
        base = sum(cdfg.node(n).latency for n in s.node_ids) \
            + extra.get(s.id, 0)
        s.ii, s.latency = _scaled_stage_timing(
            s.scc_ii, base, part.transforms)
    part.channels = derive_channels(part)
    return part


def partition_cdfg(
    cdfg: CDFG,
    *,
    policy: str = "paper",
    latency_model: LatencyModel | None = None,
    duplicate_cheap: bool = True,
    channel_cost_bytes: int = 4096,
) -> Partition:
    """Map a CDFG to the dataflow architectural template.

    policy:
      "paper"      — Algorithm 1 verbatim.
      "fused"      — single stage (the conventional accelerator).
      "maximal"    — one node per stage (fine-grained dataflow machine).
      "cost_aware" — Algorithm 1 + channel-cost driven stage merging.

    Orchestrates :func:`stage_groups` → :func:`merge_costly_boundaries` →
    :func:`materialize` → :func:`duplicate_cheap_rewrite`; the compiler
    driver (``repro.dataflow``) runs the same steps as named passes.
    ``latency_model`` is accepted for API compatibility; latencies are
    fixed at CDFG construction.
    """
    del latency_model
    plan = stage_groups(cdfg, policy=policy)
    if policy == "cost_aware" and len(plan.groups) > 1:
        plan = merge_costly_boundaries(cdfg, plan, channel_cost_bytes)
    part = materialize(cdfg, plan)

    # --- §III-B1: duplicate cheap SCCs instead of cutting a channel ----------
    if duplicate_cheap and policy not in ("fused",):
        duplicate_cheap_rewrite(part)
    return part


def _merge_costly_boundaries(
    cdfg: CDFG,
    sccs: list[set[int]],
    groups: list[list[int]],
    channel_cost_bytes: int,
) -> list[list[int]]:
    """Cost-aware refinement: merge a stage boundary when the bytes that
    would cross it exceed ``channel_cost_bytes`` *and* neither side contains
    a memory op (merging memory stages would defeat stall localization)."""
    scc_of_node = {n: k for k, comp in enumerate(sccs) for n in comp}
    changed = True
    while changed and len(groups) > 1:
        changed = False
        for b in range(len(groups) - 1):
            left = {n for k in groups[b] for n in sccs[k]}
            right = {n for k in groups[b + 1] for n in sccs[k]}
            left_mem = any(cdfg.node(n).is_memory for n in left)
            right_mem = any(cdfg.node(n).is_memory for n in right)
            if left_mem or right_mem:
                continue
            xbytes = 0
            seen = set()
            for e in cdfg.edges:
                if e.var is None or e.var in seen:
                    continue
                if e.src in left and e.dst in right:
                    xbytes += _var_nbytes(e.var)
                    seen.add(e.var)
            if xbytes > channel_cost_bytes:
                groups[b] = groups[b] + groups[b + 1]
                del groups[b + 1]
                changed = True
                break
    # keep scc_of_node referenced for clarity (deterministic rebuild upstream)
    del scc_of_node
    return groups


def _duplicate_cheap_sccs(part: Partition) -> None:
    """§III-B1: frequently-occurring cheap SCCs (loop counters and other
    single-cycle integer ops) are replicated into consumer stages rather than
    paying for a FIFO.  Long-latency ops and memory accesses are never
    duplicated (paper rule)."""
    cdfg = part.cdfg
    for node in cdfg.nodes:
        if node.is_memory or node.is_long:
            continue
        if node.prim not in CHEAP_PRIMITIVES:
            continue
        src_stage = part.stage_of_node[node.id]
        consumer_stages = sorted({
            part.stage_of_node[e.dst]
            for e in cdfg.edges
            if e.src == node.id and e.var is not None
            and part.stage_of_node[e.dst] != src_stage
        })
        if not consumer_stages:
            continue
        # only duplicate if every producer feeding this node is available in
        # the consumer stage (i.e. its inputs are jaxpr invars or themselves
        # duplicable/visible) — conservative: inputs must be graph inputs.
        # Token edges (memory-order / carry, ``var is None``) count as
        # feeders too: they carry an ordering constraint that a replica in
        # the consumer stage would silently drop.
        feeders = [e for e in cdfg.edges if e.dst == node.id]
        if feeders:
            continue
        part.duplicated[node.id] = consumer_stages


# ---------------------------------------------------------------------------
# Partition-space moves (the DSE layer, after HIDA / de Fine Licht et al.)
#
# A :class:`StagePlan` is the unit the explorer works on: ``groups`` is an
# ordered list of SCC-id lists, each a contiguous run of the fixed topo
# order.  The legal moves — merging two adjacent stages, splitting a stage
# at an interior point — keep that shape, so SCCs are never split and the
# topological order of the condensation is preserved by construction.
# ``plan_is_legal`` re-checks both invariants independently (tests, and a
# guard against hand-built plans).
# ---------------------------------------------------------------------------


def plan_signature(plan: StagePlan) -> tuple[tuple[int, ...], ...]:
    """Canonical identity of a plan's stage grouping (for dedup): the
    SCC groups, each named by its sorted member node ids."""
    return tuple(tuple(sorted(n for k in grp for n in plan.sccs[k]))
                 for grp in plan.groups)


def plan_is_legal(cdfg: CDFG, plan: StagePlan) -> bool:
    """A plan is legal iff (a) its groups partition the SCC set, (b) no
    SCC is split across groups (structural: groups hold whole SCC ids),
    (c) every cross-group dependence edge flows forward — i.e. the
    group order is a topological order of the condensation — and
    (d) channel re-derivation preserves every §III-A memory-ordering
    token: a ``mem`` edge whose endpoint the plan does not cover would
    be silently dropped by :func:`derive_channels` (``stage_of_node
    .get`` skips it), losing the store-ordering guarantee.  This is the
    one legality oracle the DSE move generation and the static verifier
    (``repro.dataflow.verify``) share."""
    seen: list[int] = [k for grp in plan.groups for k in grp]
    if sorted(seen) != list(range(len(plan.sccs))):
        return False
    group_of: dict[int, int] = {}
    for gi, grp in enumerate(plan.groups):
        for k in grp:
            group_of[k] = gi
    for e in cdfg.edges:
        a = plan.scc_of_node.get(e.src)
        b = plan.scc_of_node.get(e.dst)
        if a is None or b is None:
            # uncovered endpoint: fatal for ordering tokens (d); plain
            # data edges to uncovered nodes never materialize either
            return False
        ga, gb = group_of.get(a), group_of.get(b)
        if ga is None or gb is None:
            return False
        if a != b and ga > gb:
            return False
    return True


def merge_move(plan: StagePlan, b: int) -> StagePlan:
    """Merge adjacent groups ``b`` and ``b+1`` (always legal)."""
    groups = [list(g) for g in plan.groups]
    groups[b] = groups[b] + groups[b + 1]
    del groups[b + 1]
    return dataclasses.replace(plan, groups=groups)


def split_move(plan: StagePlan, b: int, j: int) -> StagePlan:
    """Split group ``b`` before its ``j``-th SCC (0 < j < len(group));
    both halves keep their relative (topological) order, so the move is
    always legal."""
    groups = [list(g) for g in plan.groups]
    grp = groups[b]
    if not 0 < j < len(grp):
        raise ValueError(f"split point {j} outside group of {len(grp)}")
    groups[b:b + 1] = [grp[:j], grp[j:]]
    return dataclasses.replace(plan, groups=groups)


def neighbor_plans(plan: StagePlan) -> list[tuple[str, StagePlan]]:
    """All single-move neighbours of ``plan``: every adjacent merge and
    every interior split, with a human-readable move tag."""
    out: list[tuple[str, StagePlan]] = []
    for b in range(len(plan.groups) - 1):
        out.append((f"merge({b},{b + 1})", merge_move(plan, b)))
    for b, grp in enumerate(plan.groups):
        for j in range(1, len(grp)):
            out.append((f"split({b}@{j})", split_move(plan, b, j)))
    return out


def fused_plan(plan: StagePlan) -> StagePlan:
    """The all-merged degenerate point of the move set (policy 'fused')."""
    groups = [[k for grp in plan.groups for k in grp]] if plan.groups else []
    return dataclasses.replace(plan, groups=groups)


def maximal_plan(plan: StagePlan) -> StagePlan:
    """The all-split degenerate point (policy 'maximal')."""
    return dataclasses.replace(
        plan, groups=[[k] for grp in plan.groups for k in grp])


def derive_channels(part: Partition) -> list[Channel]:
    """Every dependence edge crossing a stage boundary becomes a FIFO channel
    (§III-A last ¶): one channel per (var, src, dst) triple; memory-order
    edges become zero-width token channels.  Under an unroll transform a
    token carries U iterations' worth of payload, so data channels widen
    ×U (the FIFO bit accounting the DSE prunes against scales with them;
    token channels stay zero-width)."""
    unroll = int(getattr(part.transforms, "unroll", 1) or 1)
    seen: set[tuple[int, int, Any]] = set()
    channels: list[Channel] = []
    for e in part.cdfg.edges:
        s_src = part.stage_of_node.get(e.src)
        s_dst = part.stage_of_node.get(e.dst)
        if s_src is None or s_dst is None or s_src == s_dst:
            continue
        # duplicated producers don't need a channel into their consumers
        if e.src in part.duplicated and s_dst in part.duplicated[e.src]:
            continue
        key = (s_src, s_dst, e.var)
        if key in seen:
            continue
        seen.add(key)
        channels.append(Channel(
            src_stage=s_src,
            dst_stage=s_dst,
            var=e.var,
            nbytes=_var_nbytes(e.var) * unroll if e.var is not None else 0,
            kind=e.kind,
        ))
    return channels

"""Optimizer substrate: sharded AdamW, schedules, gradient compression."""

from .adamw import AdamWConfig, apply_updates, global_norm, init_opt_state
from .schedule import warmup_cosine
from . import compress

__all__ = ["AdamWConfig", "apply_updates", "global_norm", "init_opt_state",
           "warmup_cosine", "compress"]

"""Sharded AdamW with gradient clipping and weight-decay masks.

Self-contained (no optax dependency in the image).  Optimizer state is a
pytree congruent with the params, so the same sharding rules apply —
ZeRO-style sharding of (m, v) over the ``data`` axis falls out of the
param sharding tree for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    #: keep m/v (and the update math) in fp32 even for bf16 params
    state_dtype: Any = jnp.float32


def _decay_mask(path: tuple, leaf) -> bool:
    """No weight decay on norms, biases, 1-D params (standard practice)."""
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    if any(str(n) in ("scale", "bias", "dt_bias", "A_log", "D",
                      "decay_w0", "bonus_u") for n in names):
        return False
    return jnp.ndim(leaf) >= 2


def init_opt_state(params: Any, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params: Any, grads: Any, state: dict, cfg: AdamWConfig,
                  lr_scale: jax.Array | float = 1.0) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, info)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    masks = {id_: _decay_mask(p, l) for id_, (p, l) in enumerate(paths)}

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["mu"])
    flat_v = jax.tree_util.tree_leaves(state["nu"])

    new_p, new_m, new_v = [], [], []
    for i, (p, g, m, v) in enumerate(zip(flat_p, flat_g, flat_m, flat_v)):
        g32 = g.astype(cfg.state_dtype) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and masks[i]:
            update = update + cfg.weight_decay * p.astype(cfg.state_dtype)
        new_p.append((p.astype(cfg.state_dtype)
                      - lr * update).astype(p.dtype))
        new_m.append(m)
        new_v.append(v)

    params_out = jax.tree_util.tree_unflatten(treedef, new_p)
    state_out = {
        "mu": jax.tree_util.tree_unflatten(treedef, new_m),
        "nu": jax.tree_util.tree_unflatten(treedef, new_v),
        "count": count,
    }
    info = {"grad_norm": gnorm, "lr": lr}
    return params_out, state_out, info

"""Int8 gradient compression for the cross-pod all-reduce.

At 512+ chips the gradient reduce crosses the pod boundary (DCN/optical),
which is an order of magnitude slower than in-pod ICI.  The distributed-
optimization trick: quantize the *cross-pod* contribution to int8 with a
per-chunk fp32 scale (≈4× fewer bytes than fp32, 2× fewer than bf16),
psum the int8 payload (values stay exact: int8 values summed over ≤2¹⁵
pods fit int32), and rescale.

Error behaviour: symmetric stochastic-free quantization with per-chunk
max-abs scaling; worst-case relative error per element 1/127 per chunk,
zero-mean in aggregate.  An optional error-feedback buffer (residual
carry) makes the compression unbiased over steps (Seide et al., 1-bit
SGD lineage).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, chunk: int = 256
                  ) -> tuple[jax.Array, jax.Array]:
    """Per-chunk symmetric int8 quantization.  Returns (q, scales)."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % chunk
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    c = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(c), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape: tuple,
                    dtype=jnp.float32) -> jax.Array:
    c = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return c.reshape(-1)[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str, chunk: int = 256
                    ) -> jax.Array:
    """psum(x) over ``axis_name`` with int8 payload.

    Two-phase: (1) a tiny fp32 max-reduce agrees on one scale per chunk
    (bytes: 1/chunk of the tensor), (2) every shard quantizes with the
    *shared* scale and the int8 payloads are summed in int32 — exact
    integer addition, so the only error is the initial per-element
    quantization (≤ scale/2 per contributor).  Use on the slow (pod) axis
    only; in-pod reduces stay full precision.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % chunk
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    c = flat.reshape(-1, chunk)
    local_max = jnp.max(jnp.abs(c), axis=1)
    shared_max = jax.lax.pmax(local_max, axis_name)
    scale = jnp.maximum(shared_max / 127.0, 1e-12)
    q = jnp.clip(jnp.round(c / scale[:, None]), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = qsum.astype(jnp.float32) * scale[:, None]
    size = 1
    for s in x.shape:
        size *= s
    return out.reshape(-1)[:size].reshape(x.shape).astype(x.dtype)


def compress_tree_psum(grads: Any, axis_name: str) -> Any:
    return jax.tree_util.tree_map(
        lambda g: compressed_psum(g, axis_name), grads)


class ErrorFeedback:
    """Residual carry for unbiased long-run compression."""

    @staticmethod
    def init(params: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads: Any, residual: Any) -> tuple[Any, Any]:
        """Add carried residual; return (corrected_grads, new_residual_fn)
        — caller computes new residual as corrected - quantized."""
        corrected = jax.tree_util.tree_map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residual)
        return corrected, corrected

"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init; tests
and benches must keep seeing one device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e production topology: 16×16 = 256 chips per pod; 2 pods = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for subprocess tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))

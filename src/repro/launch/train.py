"""Training driver: config-driven, checkpointed, fault-tolerant.

Usage (CPU-scale example — the quickstart):

    PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-135m --reduced --steps 50 --batch 8 --seq 64 \
        --ckpt-dir /tmp/run0

The same driver is what a real launch uses: swap ``--reduced`` for the full
config and give it a real mesh.  Auto-resumes from the newest checkpoint in
``--ckpt-dir``; the data pipeline is deterministic in the step index, so a
resumed run consumes exactly the batches it would have seen uninterrupted.
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp

from ..checkpoint.checkpointer import Checkpointer
from ..configs.base import load_config, reduced as reduce_config
from ..data.pipeline import DataConfig, prefetched, synthetic_stream
from ..optim import adamw
from ..runtime.fault_tolerance import StepFailure, StragglerPolicy
from ..models import init_params
from .steps import TrainState, make_train_step

log = logging.getLogger("repro.train")


def train_loop(cfg, *, steps: int, batch_size: int, seq_len: int,
               ckpt_dir: str | None = None, ckpt_every: int = 20,
               lr: float = 3e-4, seed: int = 0,
               fail_at: int | None = None,
               schedule_steps: int | None = None,
               log_every: int = 10) -> dict:
    """Returns final metrics dict (loss history, failures, restores).

    ``schedule_steps``: total LR-schedule horizon; pass the final target
    when training in restartable chunks so a resumed run sees the same
    schedule as an uninterrupted one.
    """
    horizon = schedule_steps or steps
    opt_cfg = adamw.AdamWConfig(lr=lr)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, total_steps=horizon,
                                      warmup_steps=max(1, horizon // 20)),
                      donate_argnums=(0,))

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start_step = 0
    params = init_params(jax.random.PRNGKey(seed), cfg)
    state = TrainState(params, adamw.init_opt_state(params, opt_cfg),
                       jnp.zeros((), jnp.int32))
    if ckpt is not None and ckpt.latest_step() is not None:
        state, start_step = ckpt.restore(state)
        log.info("resumed from step %d", start_step)

    dcfg = DataConfig(batch_size=batch_size, seq_len=seq_len,
                      vocab_size=cfg.vocab_size, seed=seed)

    def make_source(at_step: int):
        return prefetched(synthetic_stream(dcfg, start_step=at_step),
                          depth=4)

    source = make_source(start_step)
    straggler = StragglerPolicy()

    losses: list[float] = []
    failures = restores = 0
    injected = set()
    t0 = time.time()
    step = start_step
    while step < steps:
        try:
            if (fail_at is not None and step == fail_at
                    and step not in injected):
                injected.add(step)
                raise StepFailure(f"injected failure at step {step}")
            batch = straggler.next_batch(source)
            state, metrics = step_fn(state, {"tokens": batch["tokens"]})
        except (StepFailure, RuntimeError) as e:
            # Recovery = restore state AND rewind the loop + data stream to
            # the checkpoint step; the deterministic pipeline then replays
            # exactly the batches an uninterrupted run would have seen.
            failures += 1
            if ckpt is None or ckpt.latest_step() is None:
                raise
            log.warning("step %d failed (%s); restoring", step, e)
            ckpt.wait()
            state, at = ckpt.restore(state)
            restores += 1
            del losses[at - start_step:]
            step = at
            source = make_source(at)
            continue
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            log.info("step %4d loss %.4f (%.2f s/step)", step, loss,
                     (time.time() - t0) / max(1, step - start_step + 1))
        step += 1
        if ckpt is not None and step % ckpt_every == 0:
            ckpt.save(step, state)
    if ckpt is not None:
        ckpt.save(steps, state, blocking=True)
    return {
        "losses": losses,
        "failures": failures,
        "restores": restores,
        "straggler_reuse": straggler.reused,
        "final_loss": losses[-1] if losses else None,
        "state": state,
    }


def main() -> None:
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true",
                   help="shrink to CPU-smoke size (keeps structure)")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--fail-at", type=int, default=None,
                   help="inject a failure at this step (FT demo)")
    args = p.parse_args()

    cfg = load_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    out = train_loop(cfg, steps=args.steps, batch_size=args.batch,
                     seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, lr=args.lr,
                     fail_at=args.fail_at)
    print(f"final loss: {out['final_loss']:.4f}  "
          f"failures={out['failures']} restores={out['restores']} "
          f"straggler_reuse={out['straggler_reuse']}")


if __name__ == "__main__":
    main()

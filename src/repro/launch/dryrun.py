import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production mesh needs 512 placeholder devices.  Do not import
this module from tests or benches (they must see one device).

Per cell this produces:
  * compiled.memory_analysis()  — proves the program fits (bytes/device)
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline
  * collective byte counts      — parsed from the compiled HLO
  * the three roofline terms (compute / memory / collective, seconds)

Results are appended to experiments/dryrun/<cell>.json for the roofline
table and EXPERIMENTS.md.
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs.base import (ARCH_IDS, SHAPES, cell_is_applicable,
                                load_config)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell
from repro.runtime.sharding import (HBM_BW, HBM_BYTES_PER_CHIP,
                                    ICI_BW_PER_LINK, PEAK_FLOPS_BF16)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _parse_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string like 'bf16[8,128]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # e.g.:  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=...
        m = re.match(r"[%\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
                     r"([\w\-]+)\(", line)
        if not m:
            continue
        op = m.group(2)
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                out[c] += _parse_bytes(m.group(1))
                out["count"][c] += 1
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def roofline_terms(flops: float, bytes_hbm: float, coll_bytes: float,
                   n_chips: int) -> dict:
    """The three per-step time lower bounds (seconds).

    All inputs are PER-PARTITION quantities: XLA's cost_analysis on an SPMD
    module reports the per-device program (verified empirically: an 8-way
    sharded matmul reports 1/8th of the single-device flops), and the parsed
    HLO is likewise the per-device program.
    """
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_hbm / HBM_BW
    t_coll = coll_bytes / ICI_BW_PER_LINK
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
    }


def dataflow_census(cfg, shape, *, opt_cfg=None) -> dict:
    """Stage/channel census of the cell's step function through the
    ``repro.dataflow`` compiler driver (analysis passes only: the step is
    traced with abstract inputs, partitioned by Algorithm 1, and the
    schedule summarized — nothing executes)."""
    from repro.configs.base import SHAPES as _SHAPES
    from repro.dataflow import compile as dataflow_compile
    from repro.launch import steps
    from repro.models import model as M
    from repro.optim import adamw

    if isinstance(shape, str):
        shape = _SHAPES[shape]
    specs = M.input_specs(cfg, shape)
    if shape.kind == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig()
        fn = steps.make_train_step(cfg, opt_cfg)
        args = (steps.abstract_train_state(cfg, opt_cfg), specs)
    else:
        params = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        if shape.kind == "prefill":
            fn = steps.make_forward(cfg)
            args = (params, specs.get("tokens", specs.get("embeds")))
        else:
            fn = steps.make_decode_step(cfg)
            args = (params, specs["token"], specs["cache"],
                    specs["length"])
    # use_cache=False: census cells are compiled once each, and caching
    # them would pin every model-sized jaxpr + pass products for the
    # whole matrix run
    compiled = dataflow_compile(fn, *args, backend="xla", use_cache=False)
    sch = compiled.schedule
    return {
        "ops": len(compiled.cdfg.nodes),
        "memory_ops": len(compiled.cdfg.memory_nodes),
        "long_ops": len(compiled.cdfg.long_nodes),
        "stages": sch.num_stages,
        "channels": sch.num_channels,
        "channel_bytes": sch.channel_bytes,
        "pipeline_ii": sch.pipeline_ii,
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = "experiments/dryrun",
             save: bool = True, variant: str | None = None,
             overrides: dict | None = None,
             ep_serve: bool = False,
             dataflow: bool = True) -> dict:
    """``variant``/``overrides``/``ep_serve`` support the §Perf hillclimb:
    overrides are dataclasses.replace'd onto the config (e.g.
    ``{"mla_absorbed": True, "kv_cache_dtype": "int8"}``)."""
    import dataclasses as _dc

    cfg = load_config(arch)
    if overrides:
        moe_over = overrides.pop("moe", None)
        if moe_over and cfg.moe is not None:
            cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, **moe_over))
        if overrides:
            cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}"
    if variant:
        cell += f"__{variant}"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": n_chips, "variant": variant}

    if not cell_is_applicable(cfg, shape):
        rec["status"] = "skip"
        rec["reason"] = ("long_500k requires sub-quadratic decode; "
                         f"{arch} is pure full-attention")
        return _save(rec, cell, out_dir, save)

    t0 = time.time()
    try:
        lowered, meta = lower_cell(cfg, shape, mesh, ep_serve=ep_serve)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # jax<0.6 returns [dict]
            ca = ca[0] if ca else {}
        flops = float(ca.get("flops", 0.0))
        bytes_acc = float(ca.get("bytes accessed", 0.0))
        rec["hlo_flops"] = flops
        rec["hlo_bytes"] = bytes_acc

        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in ("generated_code_size_in_bytes",
                         "argument_size_in_bytes",
                         "output_size_in_bytes",
                         "temp_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    rec[f"mem_{attr}"] = int(v)

        hlo = compiled.as_text()
        rec["coll"] = collective_bytes(hlo)
        rec["hlo_lines"] = hlo.count("\n")

        # analytic per-device weights+state bytes (the fit check)
        rec["fit"] = _fit_analysis(cfg, shape, n_chips)

        # stage/channel census from the dataflow compiler driver
        if dataflow:
            try:
                rec["dataflow"] = dataflow_census(cfg, shape)
            except Exception as e:  # noqa: BLE001 — census is best-effort
                rec["dataflow"] = {"error": f"{type(e).__name__}: {e}"}

        # roofline: cost_analysis + HLO text are already per-partition
        rec["roofline"] = roofline_terms(flops, bytes_acc,
                                         rec["coll"]["total"], n_chips)
        # model-FLOPs utilization context (6·N·D train / 2·N·D inference,
        # N = active params for MoE) — global, so compare against
        # n_chips × per-device HLO flops.
        N = (cfg.active_param_count() if cfg.moe is not None
             else cfg.param_count())
        toks = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
        mult = 6 if shape.kind == "train" else 2
        rec["model_flops"] = float(mult * N * toks)
        rec["model_vs_hlo_flops"] = (rec["model_flops"]
                                     / (flops * n_chips)
                                     if flops else None)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record and continue the matrix
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return _save(rec, cell, out_dir, save)


def _fit_analysis(cfg, shape, n_chips: int) -> dict:
    """Analytic bytes/chip for weights (+opt state if train, +cache if
    decode), assuming the 2-D sharding spreads params over all chips."""
    pbytes = cfg.param_count() * 2  # bf16
    out = {"param_bytes_global": pbytes}
    if shape.kind == "train":
        state = pbytes + cfg.param_count() * 4 * 2  # fp32 m+v
        per_chip = state / n_chips
        out["train_state_per_chip"] = per_chip
        out["fits_hbm"] = bool(per_chip < 0.9 * HBM_BYTES_PER_CHIP)
        if not out["fits_hbm"]:
            need = int(np.ceil(state / (0.9 * HBM_BYTES_PER_CHIP) / 256))
            out["pods_needed"] = need
    else:
        per_chip = pbytes / min(n_chips, 256)
        out["serve_params_per_chip"] = per_chip
        out["fits_hbm"] = bool(per_chip < 0.9 * HBM_BYTES_PER_CHIP)
    return out


def _save(rec: dict, cell: str, out_dir: str, save: bool) -> dict:
    if save:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{cell}.json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" flops={rec['hlo_flops']:.3g}"
                 f" coll={rec['coll']['total']:.3g}B"
                 f" dom={r['dominant']}"
                 f" compile={rec.get('compile_s')}s")
    elif status == "error":
        extra = " " + rec["error"][:120]
    print(f"[{status:5s}] {cell}{extra}", flush=True)
    return rec


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="all", help="arch id or 'all'")
    p.add_argument("--shape", default="all",
                   help="shape name or 'all'")
    p.add_argument("--mesh", default="single",
                   choices=["single", "multi", "both"])
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--seq-parallel", action="store_true",
                   help="apply sequence-parallel activation constraints "
                        "(§Perf B3 — measured 7.7x less wire traffic)")
    args = p.parse_args()

    ctx = None
    if args.seq_parallel:
        from repro.runtime.sharding import sequence_parallel
        ctx = sequence_parallel()
        ctx.__enter__()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out)
                n_ok += rec["status"] == "ok"
                n_err += rec["status"] == "error"
                n_skip += rec["status"] == "skip"
    print(f"done: {n_ok} ok, {n_skip} skip, {n_err} error")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Step functions (train / prefill / decode) with production shardings.

These are the units the dry-run lowers and the drivers execute.  All
sharding decisions live in runtime/sharding.py; this module only assembles
jit-wrapped callables plus ShapeDtypeStruct input trees.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig, SHAPES
from ..models import model as M
from ..optim import adamw
from ..optim.schedule import warmup_cosine
from ..runtime import sharding as shr


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: dict
    step: jax.Array


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt", "step"], meta_fields=[])


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    *, total_steps: int = 10_000, warmup_steps: int = 200):
    """Pure train step: (state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch: dict
                   ) -> tuple[TrainState, dict]:
        (loss, metrics), grads = jax.value_and_grad(
            M.loss_fn, has_aux=True)(state.params, batch, cfg)
        lr_scale = warmup_cosine(state.step, warmup_steps=warmup_steps,
                                 total_steps=total_steps)
        params, opt, info = adamw.apply_updates(
            state.params, grads, state.opt, opt_cfg, lr_scale)
        metrics.update(info)
        return TrainState(params, opt, state.step + 1), metrics

    return train_step


def abstract_train_state(cfg: ModelConfig,
                         opt_cfg: adamw.AdamWConfig) -> TrainState:
    """ShapeDtypeStruct train state (no allocation)."""
    params = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: adamw.init_opt_state(params, opt_cfg))
    return TrainState(params, opt,
                      jax.ShapeDtypeStruct((), jnp.int32))


def train_state_shardings(mesh: Mesh, state: TrainState) -> TrainState:
    psh = shr.params_shardings(mesh, state.params)
    # optimizer moments shard exactly like their params (ZeRO-for-free)
    osh = {
        "mu": jax.tree_util.tree_map(
            lambda s: s, psh),
        "nu": jax.tree_util.tree_map(lambda s: s, psh),
        "count": NamedSharding(mesh, P()),
    }
    return TrainState(psh, osh, NamedSharding(mesh, P()))


def batch_shardings(mesh: Mesh, batch_specs: dict) -> dict:
    out = {}
    for k, v in batch_specs.items():
        if k == "cache":
            out[k] = shr.tree_shardings(mesh, v, shr.cache_pspec)
        else:
            out[k] = NamedSharding(mesh, shr.batch_pspec(mesh, v.shape))
    return out


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, inputs):
        return M.prefill(params, inputs, cfg, max_len)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, cache, length):
        return M.decode_step(params, token, cache, length, cfg)
    return decode_step


def make_forward(cfg: ModelConfig):
    def fwd(params, inputs):
        logits, _ = M.forward(params, inputs, cfg)
        return logits
    return fwd


# ---------------------------------------------------------------------------
# Lowering assembly for one (arch × shape × mesh) cell
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: InputShape | str, mesh: Mesh,
               *, opt_cfg: adamw.AdamWConfig | None = None,
               donate: bool = True, ep_serve: bool = False):
    """Build and ``.lower()`` the step for one dry-run cell.

    Returns (lowered, meta) where meta records what was lowered.
    ``ep_serve`` selects the expert-resident serving layout (§Perf).
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    specs = M.input_specs(cfg, shape)

    if shape.kind == "train":
        state = abstract_train_state(cfg, opt_cfg)
        st_sh = train_state_shardings(mesh, state)
        b_sh = batch_shardings(mesh, specs)
        step = make_train_step(cfg, opt_cfg)
        jitted = jax.jit(
            step,
            in_shardings=(st_sh, b_sh),
            donate_argnums=(0,) if donate else (),
        )
        with mesh:
            lowered = jitted.lower(state, specs)
        return lowered, {"kind": "train", "inputs": specs}

    if shape.kind == "prefill":
        params = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        pbytes = sum(l.size * l.dtype.itemsize
                     for l in jax.tree_util.tree_leaves(params))
        psh = shr.params_shardings_serve(mesh, params, pbytes)
        inp = specs.get("tokens", specs.get("embeds"))
        in_sh = NamedSharding(mesh, shr.batch_pspec(mesh, inp.shape))
        fwd = make_forward(cfg)
        jitted = jax.jit(fwd, in_shardings=(psh, in_sh))
        with mesh:
            lowered = jitted.lower(params, inp)
        return lowered, {"kind": "prefill", "inputs": specs}

    if shape.kind == "decode":
        params = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        pbytes = sum(l.size * l.dtype.itemsize
                     for l in jax.tree_util.tree_leaves(params))
        psh = shr.params_shardings_serve(mesh, params, pbytes,
                                         ep_serve=ep_serve)
        cache = specs["cache"]
        csh = shr.tree_shardings(mesh, cache, shr.cache_pspec)
        tok_sh = NamedSharding(mesh,
                               shr.batch_pspec(mesh, specs["token"].shape))
        len_sh = NamedSharding(mesh, P())
        step = make_decode_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(psh, tok_sh, csh, len_sh),
            donate_argnums=(2,) if donate else (),
        )
        with mesh:
            lowered = jitted.lower(params, specs["token"], cache,
                                   specs["length"])
        return lowered, {"kind": "decode", "inputs": specs}

    raise ValueError(shape.kind)

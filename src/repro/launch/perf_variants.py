import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lower the three chosen cells with each
optimization variant and record the artifacts next to the baselines.

Cells (chosen per the spec from the baseline roofline table):
  A. deepseek-v3-671b × decode_32k  — worst roofline fraction
  B. deepseek-v3-671b × train_4k    — most collective-bound
  C. qwen2.5-14b × decode_32k       — most representative of the paper's
                                       technique (decode = the decoupled
                                       memory stage)
"""

from repro.launch.dryrun import run_cell

VARIANTS = [
    # cell A
    ("deepseek-v3-671b", "decode_32k", "absorbed",
     {"mla_absorbed": True}, False),
    ("deepseek-v3-671b", "decode_32k", "absorbed_ep",
     {"mla_absorbed": True}, True),
    ("deepseek-v3-671b", "decode_32k", "absorbed_ep_int8a2a",
     {"mla_absorbed": True, "moe": {"dispatch_dtype": "int8"}}, True),
    # cell B
    ("deepseek-v3-671b", "train_4k", "int8a2a",
     {"moe": {"dispatch_dtype": "int8"}}, False),
    ("deepseek-v3-671b", "train_4k", "int8a2a_devlim",
     {"moe": {"dispatch_dtype": "int8", "route_groups": 16,
              "route_device_limit": 4}}, False),
    # cell C
    ("qwen2.5-14b", "decode_32k", "int8kv",
     {"kv_cache_dtype": "int8"}, False),
]


def main() -> None:
    for arch, shape, name, overrides, ep in VARIANTS:
        run_cell(arch, shape, multi_pod=False, variant=name,
                 overrides=dict(overrides), ep_serve=ep)


if __name__ == "__main__":
    main()

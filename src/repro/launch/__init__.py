"""Launchers: mesh construction, dry-run, train and serve drivers.

NOTE: do not import .dryrun from here — it sets XLA_FLAGS at import time
and must only run as __main__ in its own process.
"""

from .mesh import make_debug_mesh, make_production_mesh

__all__ = ["make_debug_mesh", "make_production_mesh"]

"""Serving driver: batched prefill + decode with a continuous batch queue.

CPU-scale demo (reduced config):

    PYTHONPATH=src python -m repro.launch.serve \
        --arch smollm-135m --reduced --requests 4 --gen 16

Serving is the template end-to-end: request admission is a bounded FIFO
(HostFIFO), prefill is the burst-access stage, the KV cache is the
customized memory partition, and decode steps stream it back.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import load_config, reduced as reduce_config
from ..dataflow import dataflow_jit
from ..models import decode_step as _decode, init_params, prefill as _prefill

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int


@dataclasses.dataclass
class Result:
    id: int
    tokens: list
    prefill_s: float
    decode_s: float


class BatchedServer:
    """Static-batch server: groups requests, prefills once, decodes in
    lockstep (continuous batching is a straightforward extension — slots
    re-admit on completion; kept static for deterministic tests)."""

    def __init__(self, cfg, params, *, max_len: int = 256,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.greedy = greedy
        # Both steps go through the dataflow compiler driver.  The "xla"
        # backend executes exactly as jax.jit did, but the Compiled
        # artifact (`.lower(...)`) exposes the Algorithm-1 stage/channel
        # analysis of the serving steps — see dataflow_report().
        # on_error="fallback": a config whose step trips the analysis
        # passes still serves (plain jax.jit), it just loses the report.
        self._prefill = dataflow_jit(
            lambda p, t: _prefill(p, t, cfg, max_len), backend="xla",
            on_error="fallback")
        self._decode = dataflow_jit(
            lambda p, tok, cache, ln: _decode(p, tok, cache, ln, cfg),
            backend="xla", on_error="fallback")

    def dataflow_report(self, requests: list["Request"]) -> str:
        """Stage/channel report of the decode step for this batch shape."""
        B = len(requests)
        tok = jnp.zeros((B,), jnp.int32)
        try:
            _, cache = jax.eval_shape(
                lambda p, t: _prefill(p, t, self.cfg, self.max_len),
                self.params, jax.ShapeDtypeStruct((B, 8), jnp.int32))
            compiled = self._decode.lower(self.params, tok, cache,
                                          jnp.asarray(8, jnp.int32))
            return compiled.report()
        except Exception as e:  # noqa: BLE001 — report is best-effort
            return f"(dataflow analysis unavailable: {type(e).__name__}: {e})"

    def serve(self, requests: list[Request]) -> list[Result]:
        B = len(requests)
        S = max(len(r.prompt) for r in requests)
        # left-align prompts; pad right with zeros (masked by position)
        prompts = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            prompts[i, :len(r.prompt)] = r.prompt
        t0 = time.time()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        logits = jax.block_until_ready(logits)
        prefill_s = time.time() - t0

        gen = max(r.max_new_tokens for r in requests)
        tokens = []
        tok = (jnp.argmax(logits, -1) if self.greedy
               else jnp.argmax(logits, -1))
        t1 = time.time()
        length = jnp.asarray(S, jnp.int32)
        # lower once: shapes are fixed after prefill, so the decode loop
        # calls the Compiled artifact directly instead of re-keying the
        # params+cache pytree every token
        try:
            decode = self._decode.lower(self.params, tok.astype(jnp.int32),
                                        cache, length)
        except Exception:  # noqa: BLE001 — analysis failed; wrapper
            decode = self._decode          # falls back to jax.jit per call
        for step in range(gen):
            tokens.append(np.asarray(tok))
            logits, cache = decode(self.params, tok.astype(jnp.int32),
                                   cache, length + step)
            tok = jnp.argmax(logits, -1)
        jax.block_until_ready(logits)
        decode_s = time.time() - t1

        outs = []
        seq = np.stack(tokens, 1)  # (B, gen)
        for i, r in enumerate(requests):
            outs.append(Result(r.id, seq[i, :r.max_new_tokens].tolist(),
                               prefill_s, decode_s / gen))
        return outs


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=16)
    args = p.parse_args()

    cfg = load_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params,
                           max_len=args.prompt_len + args.gen + 8)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=(args.prompt_len,)).astype(np.int32),
                    args.gen)
            for i in range(args.requests)]
    log.info("decode-step dataflow analysis:\n%s",
             server.dataflow_report(reqs))
    t0 = time.time()
    results = server.serve(reqs)
    dt = time.time() - t0
    tok_total = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests, {tok_total} tokens "
          f"in {dt:.2f}s ({tok_total / dt:.1f} tok/s); "
          f"prefill {results[0].prefill_s:.3f}s, "
          f"decode {results[0].decode_s * 1e3:.1f} ms/tok")
    for r in results[:2]:
        print(f"  req {r.id}: {r.tokens[:8]}...")


if __name__ == "__main__":
    main()

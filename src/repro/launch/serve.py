"""Serving drivers: the resolution daemon CLI and the LM batch demo.

Resolution daemon (the serving tier of the simulation stack — see
:mod:`repro.serve` and ``docs/serving.md``):

    PYTHONPATH=src python -m repro.launch.serve daemon \
        --store-dir ~/.cache/repro-rescache
    PYTHONPATH=src python -m repro.launch.serve stats      # JSON
    PYTHONPATH=src python -m repro.launch.serve shutdown

LM serving demo (CPU-scale, reduced config) — batched prefill + decode
with a continuous batch queue:

    PYTHONPATH=src python -m repro.launch.serve \
        --arch smollm-135m --reduced --requests 4 --gen 16

The demo is the template end-to-end: request admission is a bounded
FIFO (HostFIFO), prefill is the burst-access stage, the KV cache is the
customized memory partition, and decode steps stream it back.  The
heavy imports (jax, the model zoo) are deferred so the daemon
subcommands start without them.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import sys
import time

import numpy as np

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int


@dataclasses.dataclass
class Result:
    id: int
    tokens: list
    prefill_s: float
    decode_s: float


class BatchedServer:
    """Static-batch server: groups requests, prefills once, decodes in
    lockstep (continuous batching is a straightforward extension — slots
    re-admit on completion; kept static for deterministic tests)."""

    def __init__(self, cfg, params, *, max_len: int = 256,
                 greedy: bool = True):
        from ..dataflow import dataflow_jit
        from ..models import decode_step as _decode, prefill as _prefill
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.greedy = greedy
        self._prefill_fn = _prefill
        self._decode_fn = _decode
        # Both steps go through the dataflow compiler driver.  The "xla"
        # backend executes exactly as jax.jit did, but the Compiled
        # artifact (`.lower(...)`) exposes the Algorithm-1 stage/channel
        # analysis of the serving steps — see dataflow_report().
        # on_error="fallback": a config whose step trips the analysis
        # passes still serves (plain jax.jit), it just loses the report.
        self._prefill = dataflow_jit(
            lambda p, t: _prefill(p, t, cfg, max_len), backend="xla",
            on_error="fallback")
        self._decode = dataflow_jit(
            lambda p, tok, cache, ln: _decode(p, tok, cache, ln, cfg),
            backend="xla", on_error="fallback")

    def dataflow_report(self, requests: list["Request"]) -> str:
        """Stage/channel report of the decode step for this batch shape."""
        import jax
        import jax.numpy as jnp
        B = len(requests)
        tok = jnp.zeros((B,), jnp.int32)
        try:
            _, cache = jax.eval_shape(
                lambda p, t: self._prefill_fn(p, t, self.cfg,
                                              self.max_len),
                self.params, jax.ShapeDtypeStruct((B, 8), jnp.int32))
            compiled = self._decode.lower(self.params, tok, cache,
                                          jnp.asarray(8, jnp.int32))
            return compiled.report()
        except Exception as e:  # noqa: BLE001 — report is best-effort
            return f"(dataflow analysis unavailable: {type(e).__name__}: {e})"

    def serve(self, requests: list[Request]) -> list[Result]:
        import jax
        import jax.numpy as jnp
        B = len(requests)
        S = max(len(r.prompt) for r in requests)
        # left-align prompts; pad right with zeros (masked by position)
        prompts = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            prompts[i, :len(r.prompt)] = r.prompt
        t0 = time.time()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        logits = jax.block_until_ready(logits)
        prefill_s = time.time() - t0

        gen = max(r.max_new_tokens for r in requests)
        tokens = []
        tok = (jnp.argmax(logits, -1) if self.greedy
               else jnp.argmax(logits, -1))
        t1 = time.time()
        length = jnp.asarray(S, jnp.int32)
        # lower once: shapes are fixed after prefill, so the decode loop
        # calls the Compiled artifact directly instead of re-keying the
        # params+cache pytree every token
        try:
            decode = self._decode.lower(self.params, tok.astype(jnp.int32),
                                        cache, length)
        except Exception:  # noqa: BLE001 — analysis failed; wrapper
            decode = self._decode          # falls back to jax.jit per call
        for step in range(gen):
            tokens.append(np.asarray(tok))
            logits, cache = decode(self.params, tok.astype(jnp.int32),
                                   cache, length + step)
            tok = jnp.argmax(logits, -1)
        jax.block_until_ready(logits)
        decode_s = time.time() - t1

        outs = []
        seq = np.stack(tokens, 1)  # (B, gen)
        for i, r in enumerate(requests):
            outs.append(Result(r.id, seq[i, :r.max_new_tokens].tolist(),
                               prefill_s, decode_s / gen))
        return outs


# ---------------------------------------------------------------------------
# Resolution daemon CLI
# ---------------------------------------------------------------------------

def _serve_cli(argv: list[str]) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.serve",
        description="resolution daemon control (see docs/serving.md)")
    sub = p.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("daemon", help="run the resolution daemon in "
                                      "the foreground")
    d.add_argument("--socket", default=None,
                   help="AF_UNIX path or host:port (default: the "
                        "store's canonical socket)")
    d.add_argument("--workers", type=int, default=None,
                   help="pool width (default: cores - 1, min 2)")
    d.add_argument("--store-dir", default=None,
                   help="rescache store directory to serve")
    d.add_argument("--max-queued-chunks", type=int, default=4096,
                   help="global admission cap on queued chunks")
    d.add_argument("--max-client-chunks", type=int, default=4096,
                   help="per-client outstanding-chunks budget")
    d.add_argument("--retry-budget", type=int, default=None,
                   help="chunk re-dispatches tolerated per job after "
                        "worker deaths")
    d.add_argument("--throttle", type=float, default=0.0,
                   help="seconds to sleep before each chunk dispatch "
                        "(test/debug knob)")
    d.add_argument("--no-journal", action="store_true",
                   help="disable the append-only journal (stats reset "
                        "on restart; in-flight jobs are not resumed)")
    d.add_argument("--speculate-after", type=float, default=None,
                   help="floor seconds before a straggling chunk earns "
                        "a speculative duplicate dispatch (0 disables; "
                        "default REPRO_SPECULATE_AFTER_S or 30)")
    d.add_argument("--speculate-factor", type=float, default=4.0,
                   help="chunk is a straggler past this multiple of "
                        "the observed median chunk wall")
    for name in ("stats", "shutdown"):
        sp = sub.add_parser(name)
        sp.add_argument("--socket", default=None)
    args = p.parse_args(argv)
    if args.cmd == "daemon":
        from ..core import rescache
        from ..serve import ResolutionDaemon
        if args.store_dir:
            rescache.configure(enabled=True, directory=args.store_dir)
        daemon = ResolutionDaemon(
            address=args.socket, workers=args.workers,
            max_queued_chunks=args.max_queued_chunks,
            max_client_chunks=args.max_client_chunks,
            retry_budget=args.retry_budget, throttle_s=args.throttle,
            journal=not args.no_journal,
            speculate_after_s=args.speculate_after,
            speculate_factor=args.speculate_factor)
        log.info("resolution daemon at %s (%d workers, store %s)",
                 daemon.address, daemon.workers, daemon.store_dir)
        daemon.serve_forever()
        return 0
    if args.cmd == "stats":
        from ..serve import ServeUnavailable, get_stats
        try:
            print(json.dumps(get_stats(args.socket), indent=2,
                             sort_keys=True))
        except ServeUnavailable as e:
            print(str(e), file=sys.stderr)
            return 1
        return 0
    from ..serve import shutdown
    ok = shutdown(args.socket)
    print("daemon stopped" if ok else "no daemon answered")
    return 0 if ok else 1


def _demo_main(argv: list[str]) -> None:
    import jax
    from ..configs.base import load_config, reduced as reduce_config
    from ..models import init_params

    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=16)
    args = p.parse_args(argv)

    cfg = load_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params,
                           max_len=args.prompt_len + args.gen + 8)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=(args.prompt_len,)).astype(np.int32),
                    args.gen)
            for i in range(args.requests)]
    log.info("decode-step dataflow analysis:\n%s",
             server.dataflow_report(reqs))
    t0 = time.time()
    results = server.serve(reqs)
    dt = time.time() - t0
    tok_total = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests, {tok_total} tokens "
          f"in {dt:.2f}s ({tok_total / dt:.1f} tok/s); "
          f"prefill {results[0].prefill_s:.3f}s, "
          f"decode {results[0].decode_s * 1e3:.1f} ms/tok")
    for r in results[:2]:
        print(f"  req {r.id}: {r.tokens[:8]}...")


def main(argv: list[str] | None = None) -> None:
    logging.basicConfig(level=logging.INFO)
    argv = sys.argv[1:] if argv is None else argv
    if argv and argv[0] in ("daemon", "stats", "shutdown"):
        raise SystemExit(_serve_cli(argv))
    _demo_main(argv)


if __name__ == "__main__":
    main()
